"""Observability layer (`repro.obs`): tracer/metrics semantics under
concurrency, Chrome-trace export schema, disabled-mode overhead, and the
profiled end-to-end paths the acceptance criteria pin down — a profiled
training session and a profiled serving session must each produce a
loadable trace whose spans cover >= 90% of the measured window, with
per-stage and per-op attribution (plan names included).

``test_profiled_smoke`` is the CI smoke entry point: one trace holding
train + sampler + serving spans, schema-validated.
"""
import json
import os
import sys
import threading
import time

import numpy as np
import pytest

from repro import obs
from repro.obs.export import to_chrome_trace, validate_chrome_trace, \
    write_chrome_trace

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "tools"))
import trace_summary  # noqa: E402


@pytest.fixture(autouse=True)
def _clean_obs():
    """The tracer and registry are process singletons — every test starts
    and leaves them disabled and empty."""
    obs.disable()
    obs.reset()
    obs.metrics().reset()
    yield
    obs.disable()
    obs.reset()
    obs.metrics().reset()


def _spans():
    return obs.get_tracer().snapshot()


def _names():
    return [s.name for s in _spans()]


# --------------------------------------------------------------------------
# Tracer semantics
# --------------------------------------------------------------------------

def test_span_nesting_depth_and_category():
    obs.enable()
    with obs.span("train.epoch", epoch=0):
        with obs.span("train.step", step=3):
            time.sleep(0.001)
    spans = _spans()
    # children finish (and record) before parents
    assert [s.name for s in spans] == ["train.step", "train.epoch"]
    step, epoch = spans
    assert (step.depth, epoch.depth) == (1, 0)
    assert step.attrs == {"step": 3}
    assert step.category == "train" and epoch.category == "train"
    assert step.dur_ns > 0
    # the child's interval nests inside the parent's
    assert epoch.t_start_ns <= step.t_start_ns
    assert step.t_end_ns <= epoch.t_end_ns


def test_instant_and_add_span():
    obs.enable()
    obs.instant("tuning.sweep", winner="ell")
    t1 = time.perf_counter_ns()
    obs.get_tracer().add_span("watchdog.step", t1 - 5_000_000, 5_000_000,
                              step=7)
    inst, ext = _spans()
    assert inst.dur_ns == 0 and inst.attrs == {"winner": "ell"}
    assert ext.dur_ns == 5_000_000 and ext.attrs == {"step": 7}


def test_disabled_span_is_shared_noop_and_records_nothing():
    assert not obs.enabled()
    a, b = obs.span("train.step"), obs.span("op.spmm")
    assert a is b                      # the shared no-op singleton
    with a:
        pass
    obs.instant("x")
    assert _spans() == []


def test_disabled_overhead_bound():
    # the hot loop calls span() unconditionally; disabled cost must stay
    # within a generous absolute bound (the real cost is ~100ns — the
    # bound only guards against accidentally re-introducing allocation
    # or locking on the disabled path)
    assert not obs.enabled()
    n = 50_000
    t0 = time.perf_counter()
    for _ in range(n):
        with obs.span("train.step"):
            pass
    per_call = (time.perf_counter() - t0) / n
    assert per_call < 10e-6, f"disabled span() costs {per_call * 1e9:.0f}ns"


def test_concurrent_recording_threads():
    obs.enable()
    n_threads, n_spans = 8, 200
    barrier = threading.Barrier(n_threads)

    def work(k):
        barrier.wait()
        for i in range(n_spans):
            with obs.span(f"worker.{k}", i=i):
                with obs.span(f"worker.{k}.inner"):
                    pass
            obs.metrics().counter("obs.test.total").inc()

    ts = [threading.Thread(target=work, args=(k,)) for k in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    spans = _spans()
    assert len(spans) == n_threads * n_spans * 2
    assert obs.get_tracer().n_dropped == 0
    # nesting state is per-thread: inner spans at depth 1, outers at 0
    for s in spans:
        assert s.depth == (1 if s.name.endswith(".inner") else 0), s
    assert obs.metrics().counter("obs.test.total").value \
        == n_threads * n_spans


def test_max_spans_bound_drops_and_counts():
    tr = obs.Tracer(max_spans=5)
    tr.enabled = True
    for i in range(9):
        with tr.span("x", i=i):
            pass
    assert len(tr.snapshot()) == 5
    assert tr.n_dropped == 4


def test_profiled_restores_state_and_reset_is_fresh():
    assert not obs.enabled()
    with obs.profiled():
        assert obs.enabled()
        with obs.span("a.b"):
            pass
        assert len(_spans()) == 1
    assert not obs.enabled()
    assert len(_spans()) == 1          # spans survive for export
    obs.reset()
    assert _spans() == []


def test_ops_toggle_bumps_patch_version():
    from repro.core.patch import patch_version
    v0 = patch_version()
    obs.enable(ops=True)
    v1 = patch_version()
    obs.disable()
    v2 = patch_version()
    assert v1 != v0 and v2 != v1       # jitted callers retrace both ways


# --------------------------------------------------------------------------
# Metrics registry
# --------------------------------------------------------------------------

def test_metrics_instruments_and_snapshot():
    reg = obs.MetricsRegistry()
    reg.counter("c").inc()
    reg.counter("c").inc(2.5)
    reg.gauge("g").set(0.75)
    h = reg.histogram("h")
    for v in (1.0, 2.0, 3.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["c"] == 3.5
    assert snap["g"] == 0.75
    assert snap["h"]["count"] == 3 and snap["h"]["sum"] == 6.0
    assert snap["h"]["p50"] == 2.0 and snap["h"]["max"] == 3.0


def test_histogram_empty_summary_has_zero_defaults():
    h = obs.Histogram("empty")
    s = h.summary()
    assert s == dict(count=0, sum=0.0, mean=0.0, p50=0.0, p99=0.0, max=0.0)


def test_metric_name_kind_conflict_raises():
    reg = obs.MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")


def test_histogram_reservoir_is_bounded_and_recent():
    h = obs.Histogram("lat", max_samples=16)
    for v in range(100):
        h.observe(float(v))
    assert h.count == 100
    # percentiles come from the most recent window only
    assert h.percentile(0) >= 84.0


def test_metrics_jsonl_sink(tmp_path):
    reg = obs.MetricsRegistry()
    reg.counter("reqs").inc(4)
    path = str(tmp_path / "metrics.jsonl")
    obs.metrics_to_jsonl(path, reg, run="a")
    reg.counter("reqs").inc()
    obs.metrics_to_jsonl(path, reg, run="b")
    lines = [json.loads(x) for x in open(path)]
    assert [r["metrics"]["reqs"] for r in lines] == [4, 5]
    assert [r["run"] for r in lines] == ["a", "b"]
    assert all("ts" in r for r in lines)


def test_device_counters_pytree():
    import jax
    import jax.numpy as jnp
    stats = obs.device_counters("skipped", "overflow")

    @jax.jit
    def step(s, flag):
        s = s.add("skipped", jnp.where(flag, 1, 0))
        return s.add("overflow", 3)

    for flag in (True, False, True):
        stats = step(stats, flag)
    assert stats.drain() == {"skipped": 2, "overflow": 9}
    assert int(stats["overflow"]) == 9


# --------------------------------------------------------------------------
# Chrome-trace export
# --------------------------------------------------------------------------

def test_chrome_trace_schema_and_content(tmp_path):
    obs.enable()
    obs.metrics().counter("serve.requests").inc(2)
    with obs.span("train.step", step=0, plan="bsr16x16"):
        obs.instant("tuning.sweep", winner="ell",
                    candidates=[["ell", 0.1], ["bsr16x16", 0.2]])

    def worker():
        with obs.span("loader.pack", batch=1):
            pass

    t = threading.Thread(target=worker, name="repro-prefetch")
    t.start()
    t.join()

    obj = to_chrome_trace()
    assert validate_chrome_trace(obj) == []
    events = obj["traceEvents"]
    x = [e for e in events if e["ph"] == "X"]
    i = [e for e in events if e["ph"] == "i"]
    m = [e for e in events if e["ph"] == "M"]
    assert {e["name"] for e in x} == {"train.step", "loader.pack"}
    assert [e["name"] for e in i] == ["tuning.sweep"]
    # attrs ride in args; plan names survive export
    (step,) = [e for e in x if e["name"] == "train.step"]
    assert step["args"]["plan"] == "bsr16x16"
    assert i[0]["args"]["winner"] == "ell"
    # per-thread name metadata: main + the prefetch worker
    tnames = {e["args"]["name"] for e in m if e["name"] == "thread_name"}
    assert "repro-prefetch" in tnames
    # two recording threads -> two distinct tids on the events
    assert len({e["tid"] for e in x}) == 2
    assert obj["otherData"]["metrics"]["serve.requests"] == 2
    assert obj["otherData"]["n_dropped"] == 0

    path = write_chrome_trace(str(tmp_path / "t.json"))
    assert validate_chrome_trace(json.load(open(path))) == []


def test_validate_chrome_trace_flags_violations():
    assert validate_chrome_trace([]) != []
    assert validate_chrome_trace({}) != []
    bad = {"traceEvents": [
        {"ph": "X", "name": "a", "pid": 1, "tid": 1, "ts": 0.0},  # no dur
        {"ph": "q", "name": "b"},                                 # bad ph
        {"ph": "i", "name": "", "pid": 1, "tid": 1, "ts": 0.0},   # empty name
    ]}
    errs = validate_chrome_trace(bad)
    assert len(errs) >= 3
    assert any("missing 'dur'" in e for e in errs)
    assert any("unknown ph" in e for e in errs)
    assert any("non-empty string" in e for e in errs)


def test_trace_summary_tool(tmp_path, capsys):
    obs.enable()
    with obs.span("train.epoch"):
        for i in range(3):
            with obs.span("train.step", step=i):
                time.sleep(0.001)
    obs.instant("op.spmm.trace", shapes=[[8, 8]])
    obs.instant("tuning.plan", site="build_cached_graph", source="db",
                kind="ell")
    obs.metrics().counter("cache.hits").inc(5)
    path = write_chrome_trace(str(tmp_path / "t.json"))

    s = trace_summary.summarize(trace_summary.load_trace(path))
    names = {r["name"] for r in s["rows"]}
    assert names == {"train.epoch", "train.step"}
    assert s["coverage"] > 0.9         # epoch span covers the window
    assert [c["category"] for c in s["categories"]] == ["train"]
    # union within category: nested steps don't double the layer's share
    # (float tolerance: union == wall can round a hair past 100)
    assert s["categories"][0]["pct_wall"] <= 100.0 + 1e-6
    assert s["op_counts"] == {"op.spmm.trace": 1}
    assert s["tuning"][0]["kind"] == "ell"
    assert s["metrics"]["cache.hits"] == 5

    trace_summary.main([path, "--top", "5"])
    out = capsys.readouterr().out
    assert "train.step" in out and "tuning.plan" in out \
        and "cache.hits" in out


def test_interval_union_merges_overlaps():
    evs = [{"ts": 0.0, "dur": 10.0}, {"ts": 5.0, "dur": 10.0},
           {"ts": 30.0, "dur": 5.0}]
    assert trace_summary.interval_union_us(evs) == 20.0


# --------------------------------------------------------------------------
# Watchdog + tuning integration
# --------------------------------------------------------------------------

def test_watchdog_summary_and_trace_spans():
    from repro.train.fault_tolerance import StragglerWatchdog
    wd = StragglerWatchdog(threshold=2.0)
    obs.enable()
    for step, wall in enumerate([0.1, 0.1, 0.5, 0.1]):
        wd.observe(step, wall)
    s = wd.summary()
    assert s["total_steps"] == 4 and s["straggler_count"] == 1
    assert s["straggler_frac"] == 0.25
    assert s["worst"][0]["wall_s"] == 0.5 and s["worst"][0]["straggler"]
    spans = [x for x in _spans() if x.name == "watchdog.step"]
    assert len(spans) == 4
    flagged = [x for x in spans if x.attrs["straggler"]]
    assert len(flagged) == 1 and flagged[0].attrs["step"] == 2
    # reconstructed duration matches the observed wall time
    assert flagged[0].dur_ns == int(0.5 * 1e9)


def test_watchdog_summary_empty():
    from repro.train.fault_tolerance import StragglerWatchdog
    s = StragglerWatchdog().summary()
    assert s["total_steps"] == 0 and s["straggler_frac"] == 0.0
    assert s["ema_s"] == 0.0 and s["worst"] == []


def test_tuning_decisions_recorded(rng, tmp_path):
    from repro.core.autotune import autotune
    from tests.conftest import random_coo
    coo, _ = random_coo(rng, 128, 128, 2000)
    obs.enable()
    autotune(coo, k_hint=64)
    sweeps = [s for s in _spans() if s.name == "tuning.sweep"]
    assert len(sweeps) == 1
    sw = sweeps[0].attrs
    assert "winner" in sw and sw["candidates"], sw
    assert all(len(c) == 2 for c in sw["candidates"])
    assert obs.metrics().counter("tuning.sweeps").value == 1
    # counters stay live with tracing off; the timeline stays silent
    obs.disable()
    autotune(coo, k_hint=64)
    assert obs.metrics().counter("tuning.sweeps").value == 2
    assert len([s for s in _spans() if s.name == "tuning.sweep"]) == 1


# --------------------------------------------------------------------------
# Profiled end-to-end paths (the acceptance criteria)
# --------------------------------------------------------------------------

def test_profiled_fullgraph_train_coverage(tiny_dataset):
    from repro.train.gnn import train_gnn
    with obs.profiled(ops=True):
        train_gnn("gcn", tiny_dataset, hidden=16, epochs=3, profile=True,
                  tune=True)
    obj = to_chrome_trace()
    assert validate_chrome_trace(obj) == []
    s = trace_summary.summarize(obj)
    assert s["coverage"] >= 0.9, s["coverage"]
    names = {r["name"] for r in s["rows"]}
    assert {"train.build", "train.init", "train.step",
            "train.eval"} <= names
    # plan attribution: the tuner's decisions are on the timeline
    assert any(t["name"] == "tuning.plan" for t in s["tuning"])


def test_profiled_minibatch_train_stage_breakdown(tiny_dataset):
    from repro.train.gnn_minibatch import train_gnn_minibatch
    with obs.profiled(ops=True):
        res = train_gnn_minibatch("sage-sum", tiny_dataset, fanouts=(5, 5),
                                  batch_size=64, hidden=16, epochs=1,
                                  tune=False, profile=True)
    obj = to_chrome_trace()
    assert validate_chrome_trace(obj) == []
    s = trace_summary.summarize(obj)
    assert s["coverage"] >= 0.9, s["coverage"]
    names = {r["name"] for r in s["rows"]}
    assert {"loader.sample", "loader.pack", "loader.h2d", "train.step",
            "train.epoch", "train.infer"} <= names
    # the loader stages ran on the prefetch daemon thread, the steps on
    # the main thread — distinct named tracks in the export
    by_name = {}
    for ev in obj["traceEvents"]:
        if ev.get("ph") == "X":
            by_name.setdefault(ev["name"], set()).add(ev["tid"])
    assert by_name["loader.sample"].isdisjoint(by_name["train.step"])
    # drained device counters surfaced as metrics and result fields
    assert s["metrics"]["train.skipped_steps"] == res.skipped_steps
    assert res.test_acc > 0


def test_profiled_serving_spans_and_cache_metrics(tiny_dataset):
    from repro.serving import GNNServer
    from repro.train.gnn_minibatch import train_gnn_minibatch
    res = train_gnn_minibatch("sage-sum", tiny_dataset, fanouts=(5, 5),
                              batch_size=64, hidden=16, epochs=1,
                              tune=False)
    srv = GNNServer(res.final_params, tiny_dataset, arch="sage-sum",
                    fanouts=(5, 5), tune=False, start=False,
                    cache_capacity=64)
    with obs.profiled(ops=True):
        for seeds in ([1, 2, 3], [2, 3, 4]):
            t = srv.submit(seeds)
            srv.run_pending(force=True)
            t.result(30.0)
    obj = to_chrome_trace()
    assert validate_chrome_trace(obj) == []
    s = trace_summary.summarize(obj)
    assert s["coverage"] >= 0.9, s["coverage"]
    names = {r["name"] for r in s["rows"]}
    assert {"serve.flush", "serve.sample", "serve.pack", "serve.gather",
            "serve.apply", "serve.queue_wait"} <= names
    m = s["metrics"]
    assert m["serve.requests"] == 2 and m["serve.flushes"] == 2
    assert m["cache.hits"] + m["cache.misses"] > 0
    assert m["cache.hits"] == srv.cache.stats.hits
    assert m["serve.latency_s"]["count"] == 2
    stats = srv.latency_stats()
    assert stats["p99_ms"] >= stats["p50_ms"] > 0
    assert stats["queue_wait_p99_ms"] >= 0


def test_latency_stats_idle_defaults(tiny_dataset):
    from repro.serving import GNNServer
    from repro.train.gnn_minibatch import make_block_model
    init, _, _, _ = make_block_model(
        "sage-sum", tiny_dataset.num_features, 16,
        tiny_dataset.num_classes, 2)
    import jax
    params = init(jax.random.PRNGKey(0))
    srv = GNNServer(params, tiny_dataset, arch="sage-sum", fanouts=(5, 5),
                    tune=False, start=False, cache_capacity=16)
    stats = srv.latency_stats()
    for key in ("p50_ms", "p99_ms", "mean_ms", "queue_wait_p50_ms",
                "queue_wait_p99_ms", "mean_flush_size"):
        assert stats[key] == 0.0, (key, stats)
    assert stats["requests"] == 0 and stats["flushes"] == 0


def test_profiled_smoke(tiny_dataset, tmp_path):
    """The CI smoke: one profiled trace holding training, sampler, and
    serving spans plus kernel-dispatch records, schema-valid on disk."""
    from repro.serving import GNNServer
    from repro.train.gnn_minibatch import train_gnn_minibatch
    with obs.profiled(ops=True):
        res = train_gnn_minibatch("sage-sum", tiny_dataset, fanouts=(5, 5),
                                  batch_size=64, hidden=16, epochs=1,
                                  tune=False, profile=True)
        srv = GNNServer(res.final_params, tiny_dataset, arch="sage-sum",
                        fanouts=(5, 5), tune=False, start=False,
                        cache_capacity=64)
        t = srv.submit([1, 2, 3])
        srv.run_pending(force=True)
        t.result(30.0)
    path = write_chrome_trace(str(tmp_path / "smoke_trace.json"))
    obj = json.load(open(path))
    assert validate_chrome_trace(obj) == []
    cats = {str(e["name"]).split(".", 1)[0]
            for e in obj["traceEvents"] if e.get("ph") in ("X", "i")}
    assert {"train", "loader", "serve", "op"} <= cats, cats
    # the summary tool digests it end to end
    out = trace_summary.format_summary(
        trace_summary.summarize(obj))
    assert "per-span attribution" in out
