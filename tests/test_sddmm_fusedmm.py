"""SDDMM and FusedMM: forward vs explicit math, recompute-based backward vs
jax.grad of the materialized composition."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as C
from repro.kernels.ref import fusedmm_coo_ref
from conftest import random_coo


def _setup(rng, n=50, m=40, nnz=300, d=16, k=24):
    coo, dense = random_coo(rng, n, m, nnz)
    g = C.build_cached_graph(coo, k_hint=k, tune=False)
    x = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal((m, d)).astype(np.float32))
    h = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    return g, dense, x, y, h


def test_sddmm_forward(rng):
    g, dense, x, y, _ = _setup(rng)
    s = C.sddmm(g, x, y)
    coo = g.coo
    row = np.asarray(coo.row)[: coo.nse]
    col = np.asarray(coo.col)[: coo.nse]
    val = np.asarray(coo.val)[: coo.nse]
    exp = (np.asarray(x)[row] * np.asarray(y)[col]).sum(-1) * val
    np.testing.assert_allclose(np.asarray(s)[: coo.nse], exp, rtol=1e-4,
                               atol=1e-4)
    assert np.all(np.asarray(s)[coo.nse:] == 0)


def test_sddmm_grad(rng):
    g, dense, x, y, _ = _setup(rng)

    def loss(xx, yy):
        return jnp.sum(C.sddmm(g, xx, yy) ** 2)

    def loss_dense(xx, yy):
        s = (xx @ yy.T) * jnp.asarray(dense)
        return jnp.sum(s ** 2)

    gx, gy = jax.grad(loss, argnums=(0, 1))(x, y)
    gx2, gy2 = jax.grad(loss_dense, argnums=(0, 1))(x, y)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx2), rtol=1e-3,
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(gy), np.asarray(gy2), rtol=1e-3,
                               atol=1e-3)


@pytest.mark.parametrize("edge_op", ["softmax", "sigmoid", "none"])
def test_fusedmm_forward_and_grad(rng, edge_op):
    g, dense, x, y, h = _setup(rng)
    out = C.fusedmm(g, x, y, h, edge_op=edge_op)
    ref = fusedmm_coo_ref(g.coo, x, y, h, edge_op=edge_op)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4,
                               atol=1e-4)

    # custom (recompute) backward vs jax.grad through the materialized oracle
    def loss_fused(xx, yy, hh):
        return jnp.sum(C.fusedmm(g, xx, yy, hh, edge_op=edge_op) ** 2)

    def loss_ref(xx, yy, hh):
        return jnp.sum(fusedmm_coo_ref(g.coo, xx, yy, hh,
                                       edge_op=edge_op) ** 2)

    g1 = jax.grad(loss_fused, argnums=(0, 1, 2))(x, y, h)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(x, y, h)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3,
                                   atol=1e-3)
