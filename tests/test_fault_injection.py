"""Fault-injection suite: the robustness layer of the minibatch trainer.

Every failure mode `repro.testing.faults` can inject is exercised against
`train_gnn_minibatch`, on the 1-shard path in-process and on a forced-CPU
2-shard mesh in a subprocess (the main pytest process must stay
single-device, like tests/test_multidevice.py):

* kill mid-epoch + resume → bitwise-identical final params (host AND
  device samplers, 1 and 2 shards) — the deterministic-resume tentpole;
* NaN gradient on one shard → both shards skip that update in lockstep
  (no psum deadlock) and training converges near the clean run;
* prefetch-worker death → bounded restart, bitwise-equal outcome;
* device-sampler capacity overflow → counted, surfaced, escalated;
* straggler delay → watchdog flags it.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str, devices: int = 2, timeout: int = 560) -> str:
    code = ("import os\n"
            f"os.environ['XLA_FLAGS'] = "
            f"'--xla_force_host_platform_device_count={devices}'\n"
            + textwrap.dedent(body))
    env = dict(os.environ, PYTHONPATH=os.path.join(_ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@pytest.fixture(scope="module")
def ds():
    from repro.data import make_dataset
    return make_dataset("reddit", scale=1 / 512, seed=1)


_KW = dict(fanouts=(4, 4), batch_size=64, hidden=32, epochs=3, seed=0)


def _train(dataset, **over):
    from repro.train import train_gnn_minibatch
    kw = dict(_KW)
    kw.update(over)
    return train_gnn_minibatch("sage-mean", dataset, **kw)


def _leaves(params):
    import jax
    return jax.tree_util.tree_leaves(params)


def _assert_bitwise(pa, pb, what):
    for a, b in zip(_leaves(pa), _leaves(pb)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), what


# -------------------------------------------------------------------------
# kill + resume: bitwise determinism (the tentpole claim)
# -------------------------------------------------------------------------

@pytest.mark.parametrize("sampler", ["host", "device"])
def test_kill_resume_bitwise_single_shard(ds, tmp_path, sampler):
    """A run killed mid-epoch resumes from its checkpoint and finishes
    with final params bitwise-identical to the uninterrupted run. The
    kill (step 7) does not land on the ckpt cadence (every 3), so the
    resume replays steps 6..7 — the loader fast-forward path, not just a
    state reload."""
    from repro.testing import FaultPlan, expect_kill

    clean = _train(ds, sampler=sampler)
    d = str(tmp_path / sampler)
    exc = expect_kill(_train, ds, sampler=sampler, ckpt_dir=d,
                      ckpt_every=3, faults=FaultPlan(step_exception_at=7))
    assert "step 7" in str(exc)
    r = _train(ds, sampler=sampler, ckpt_dir=d, ckpt_every=3)
    assert r.resumed_step == 6, r.resumed_step        # last multiple of 3
    assert r.losses == clean.losses
    _assert_bitwise(clean.final_params, r.final_params,
                    f"{sampler}: resumed params diverged from clean run")


def test_resume_after_complete_is_noop(ds, tmp_path):
    """Resuming a finished run replays nothing and returns the same
    params and loss history (idempotent restarts — what a preempted-then-
    rescheduled job does when the preemption hit after the last step)."""
    from repro.sampling import num_seed_batches
    d = str(tmp_path / "done")
    r1 = _train(ds, ckpt_dir=d, ckpt_every=3)
    r2 = _train(ds, ckpt_dir=d, ckpt_every=3)
    spe = num_seed_batches(int(np.asarray(ds.train_mask).sum()),
                           _KW["batch_size"])
    assert r2.resumed_step == _KW["epochs"] * spe, r2.resumed_step
    assert r2.losses == r1.losses
    _assert_bitwise(r1.final_params, r2.final_params,
                    "re-run of a complete run changed params")


# -------------------------------------------------------------------------
# non-finite guard
# -------------------------------------------------------------------------

def test_nan_grad_skipped_single_shard(ds):
    """An injected NaN gradient is skipped (params/opt state keep their
    pre-step values), counted, and the run stays finite and close to the
    clean run."""
    clean = _train(ds)
    from repro.testing import FaultPlan
    r = _train(ds, faults=FaultPlan(nan_grad_at=(4, 0)))
    assert r.skipped_steps == 1, r.skipped_steps
    assert all(np.isfinite(r.losses)), r.losses
    # one skipped update out of ~15: the final loss stays in the clean
    # run's neighborhood
    assert abs(r.losses[-1] - clean.losses[-1]) < 0.5, \
        (r.losses, clean.losses)


def test_nan_guard_off_poisons_params(ds):
    """Control: with skip_nonfinite=False the same injection propagates —
    proving the guard (not luck) is what keeps the guarded run finite."""
    from repro.testing import FaultPlan
    r = _train(ds, faults=FaultPlan(nan_grad_at=(4, 0)),
               skip_nonfinite=False)
    assert not all(np.isfinite(r.losses)), r.losses


# -------------------------------------------------------------------------
# prefetch-worker death
# -------------------------------------------------------------------------

def test_prefetch_death_recovers_bitwise(ds):
    """The prefetch producer dying mid-epoch restarts from the delivered
    batch count; the recovered run is bitwise-identical to a clean one
    (no dropped and no replayed batch)."""
    clean = _train(ds)
    from repro.testing import FaultPlan
    r = _train(ds, faults=FaultPlan(prefetch_death_at=5))
    assert r.prefetch_restarts == 1, r.prefetch_restarts
    assert r.losses == clean.losses
    _assert_bitwise(clean.final_params, r.final_params,
                    "prefetch-restarted run diverged")


def test_prefetch_restarts_exhausted_raises(ds):
    """With a zero restart budget the producer's exception propagates —
    bounded retry, not infinite self-healing."""
    from repro.testing import FaultPlan, InjectedFault
    with pytest.raises(InjectedFault):
        _train(ds, faults=FaultPlan(prefetch_death_at=5),
               prefetch_restarts=0)


# -------------------------------------------------------------------------
# device-sampler capacity overflow
# -------------------------------------------------------------------------

def test_device_overflow_counted_and_escalated(ds):
    """Starving the device sampler's per-hop capacities drops edges: the
    drops must be counted (never silent) and the trainer must escalate —
    rebuild the sampler with doubled capacities — at the epoch boundary."""
    with pytest.warns(UserWarning, match="capacity overflow"):
        r = _train(ds, sampler="device", device_caps=[128, 128],
                   max_escalations=2)
    assert r.overflow_edges > 0, "starved caps must drop (and count) edges"
    assert r.capacity_escalations >= 1, r.capacity_escalations
    assert all(np.isfinite(r.losses)), r.losses
    # escalation rebuilds the step: its compile is accounted, not lost
    assert r.n_traces >= 1 + r.capacity_escalations, \
        (r.n_traces, r.capacity_escalations)


def test_device_ample_caps_no_overflow(ds):
    """Control: the probed capacities see no overflow and no escalation."""
    r = _train(ds, sampler="device")
    assert r.overflow_edges == 0 and r.capacity_escalations == 0


# -------------------------------------------------------------------------
# straggler watchdog
# -------------------------------------------------------------------------

def test_straggler_flagged(ds):
    """An injected delay on one step is flagged by the watchdog (EMA
    threshold), and only steps near it — aggregates stay bounded."""
    from repro.testing import FaultPlan
    from repro.train.fault_tolerance import StragglerWatchdog
    wd = StragglerWatchdog(threshold=3.0)
    _train(ds, faults=FaultPlan(straggler_at=6, straggler_delay_s=0.5),
           watchdog=wd, double_buffer=False)
    flagged = [e.step for e in wd.events if e.straggler]
    assert 6 in flagged, flagged
    assert wd.straggler_count >= 1
    assert wd.total_steps == len(wd.events)   # window bound not hit here
    # (the max_events deque bound itself is unit-tested in test_ckpt_ft)


# -------------------------------------------------------------------------
# 2-shard lockstep (forced-CPU subprocess)
# -------------------------------------------------------------------------

def test_kill_resume_bitwise_two_shards():
    """Kill/resume determinism on a data=2 mesh, host and device
    samplers: the lockstep schedule replay must also restore every
    shard's round counters."""
    _run("""
    import tempfile, numpy as np, jax
    from repro.data import make_dataset
    from repro.train import train_gnn_minibatch
    from repro.testing import FaultPlan, expect_kill
    ds = make_dataset('reddit', scale=1/512, seed=1)
    mesh = jax.make_mesh((2,), ('data',))
    kw = dict(fanouts=(4, 4), batch_size=64, hidden=32, epochs=3, seed=0,
              mesh=mesh)
    for sampler in ('host', 'device'):
        clean = train_gnn_minibatch('sage-mean', ds, sampler=sampler, **kw)
        assert clean.num_shards == 2
        with tempfile.TemporaryDirectory() as d:
            expect_kill(train_gnn_minibatch, 'sage-mean', ds,
                        sampler=sampler, ckpt_dir=d, ckpt_every=2,
                        faults=FaultPlan(step_exception_at=5), **kw)
            r = train_gnn_minibatch('sage-mean', ds, sampler=sampler,
                                    ckpt_dir=d, ckpt_every=2, **kw)
        assert r.resumed_step == 4, r.resumed_step
        assert r.losses == clean.losses, (sampler, r.losses, clean.losses)
        for a, b in zip(jax.tree_util.tree_leaves(clean.final_params),
                        jax.tree_util.tree_leaves(r.final_params)):
            assert np.array_equal(a, b), sampler
        print(sampler, 'bitwise OK')
    """, devices=2)


def test_nan_lockstep_skip_two_shards():
    """The acceptance criterion: a NaN gradient injected on ONE shard of
    a 2-shard run is skipped by BOTH shards in the same step (the skip
    decision is itself a psum — no deadlock; a hang would trip the
    subprocess timeout), exactly one step is skipped run-wide, and the
    run converges to within tolerance of the clean run. Exercised on
    both gradient wires — the int8 path's shared pmax'd scale is the one
    a stray NaN would poison cross-shard."""
    _run("""
    import numpy as np, jax
    from repro.data import make_dataset
    from repro.train import train_gnn_minibatch
    from repro.testing import FaultPlan
    ds = make_dataset('reddit', scale=1/512, seed=1)
    mesh = jax.make_mesh((2,), ('data',))
    kw = dict(fanouts=(4, 4), batch_size=64, hidden=32, epochs=3, seed=0,
              mesh=mesh)
    clean = train_gnn_minibatch('sage-mean', ds, **kw)
    for wire in ('fp32', 'int8'):
        r = train_gnn_minibatch('sage-mean', ds, grad_sync=wire,
                                faults=FaultPlan(nan_grad_at=(4, 1)), **kw)
        assert r.skipped_steps == 1, (wire, r.skipped_steps)
        assert all(np.isfinite(r.losses)), (wire, r.losses)
        assert abs(r.losses[-1] - clean.losses[-1]) < 0.5, \
            (wire, r.losses, clean.losses)
        print(wire, 'lockstep skip OK', r.losses[-1])
    """, devices=2)
