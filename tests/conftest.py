import numpy as np
import pytest

# NOTE: no XLA_FLAGS here on purpose — tests run on the 1-device CPU default;
# multi-device behaviour is tested via subprocesses (test_multidevice.py).


@pytest.fixture()
def rng():
    return np.random.default_rng(0)


def random_coo(rng, n, m, nnz, dtype=np.float32, pad_to=None):
    """Unique-edge random COO + its dense counterpart."""
    from repro.core import coo_from_edges

    lin = rng.choice(n * m, size=min(nnz, n * m), replace=False)
    dst, src = lin // m, lin % m
    val = rng.standard_normal(len(lin)).astype(dtype)
    coo = coo_from_edges(src, dst, val, n, m, pad_to=pad_to)
    dense = np.zeros((n, m), dtype)
    dense[dst, src] = val
    return coo, dense


@pytest.fixture
def small_graph(rng):
    return random_coo(rng, 64, 48, 500)


@pytest.fixture(scope="session")
def tiny_dataset():
    from repro.data import make_dataset
    return make_dataset("reddit", scale=1 / 512, seed=1)
