"""Data registry (Table 1 mirror), token stream, and the 10 assigned
architecture configs (exact published numbers)."""
import numpy as np
import pytest

from repro.configs import arch_names, get_config, get_smoke_config
from repro.configs.base import LM_SHAPES, shape_cells_for
from repro.data import DATASETS, dataset_names, make_dataset, rmat_edges
from repro.data.tokens import synthetic_lm_batch


def test_table1_registry():
    assert set(dataset_names()) == {"reddit", "reddit2", "ogbn-mag",
                                    "amazon", "ogbn-products",
                                    "ogbn-proteins"}
    assert DATASETS["reddit"].feat == 602
    assert DATASETS["reddit"].classes == 41
    assert DATASETS["ogbn-products"].nodes == 2_449_029
    assert DATASETS["ogbn-proteins"].feat == 8


def test_rmat_determinism_and_skew():
    s1, d1 = rmat_edges(1024, 8000, seed=3)
    s2, d2 = rmat_edges(1024, 8000, seed=3)
    np.testing.assert_array_equal(s1, s2)
    np.testing.assert_array_equal(d1, d2)
    deg = np.bincount(d1, minlength=1024)
    # power-law-ish: max degree far above mean
    assert deg.max() > 5 * deg.mean()


def test_make_dataset_shapes():
    ds = make_dataset("ogbn-proteins", scale=1 / 64, seed=0)
    assert ds.x.shape[1] == 8
    assert ds.num_classes == 112
    m = np.asarray(ds.train_mask) | np.asarray(ds.val_mask) \
        | np.asarray(ds.test_mask)
    assert m.all()
    assert not (np.asarray(ds.train_mask) & np.asarray(ds.test_mask)).any()


def test_token_stream_determinism():
    a1, b1 = synthetic_lm_batch(4, 16, 100, step=3)
    a2, b2 = synthetic_lm_batch(4, 16, 100, step=3)
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_array_equal(b1[:, :-1], a1[:, 1:])   # shifted targets


# ---- assigned architecture numbers (from the task card) -------------------

EXPECTED = {
    "hymba-1.5b":           dict(n_layers=32, d_model=1600, n_heads=25,
                                 n_kv_heads=5, d_ff=5504, vocab=32001,
                                 d_state=16, hybrid=True, n_meta_tokens=128),
    "mamba2-1.3b":          dict(n_layers=48, d_model=2048, d_ff=0,
                                 vocab=50280, d_state=128, ssm=True),
    "hubert-xlarge":        dict(n_layers=48, d_model=1280, n_heads=16,
                                 n_kv_heads=16, d_ff=5120, vocab=504,
                                 causal=False),
    "phi3.5-moe-42b-a6.6b": dict(n_layers=32, d_model=4096, n_heads=32,
                                 n_kv_heads=8, d_ff=6400, vocab=32064,
                                 n_experts=16, top_k=2),
    "mixtral-8x7b":         dict(n_layers=32, d_model=4096, n_heads=32,
                                 n_kv_heads=8, d_ff=14336, vocab=32000,
                                 n_experts=8, top_k=2, window=4096),
    "llama3-8b":            dict(n_layers=32, d_model=4096, n_heads=32,
                                 n_kv_heads=8, d_ff=14336, vocab=128256),
    "qwen1.5-4b":           dict(n_layers=40, d_model=2560, n_heads=20,
                                 n_kv_heads=20, d_ff=6912, vocab=151936,
                                 qkv_bias=True),
    "qwen2-1.5b":           dict(n_layers=28, d_model=1536, n_heads=12,
                                 n_kv_heads=2, d_ff=8960, vocab=151936,
                                 qkv_bias=True),
    "gemma-7b":             dict(n_layers=28, d_model=3072, n_heads=16,
                                 n_kv_heads=16, d_ff=24576, vocab=256000,
                                 d_head=256, act="gelu"),
    "internvl2-2b":         dict(n_layers=24, d_model=2048, n_heads=16,
                                 n_kv_heads=8, d_ff=8192, vocab=92553,
                                 n_prefix_tokens=1024),
}


@pytest.mark.parametrize("arch", arch_names())
def test_assigned_config_numbers(arch):
    cfg = get_config(arch)
    for k, v in EXPECTED[arch].items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


@pytest.mark.parametrize("arch", arch_names())
def test_smoke_config_is_reduced(arch):
    full, smoke = get_config(arch), get_smoke_config(arch)
    assert smoke.n_layers <= 4
    assert smoke.d_model <= 256
    assert smoke.vocab <= 1024
    assert smoke.family == full.family


def test_shape_cell_skip_rules():
    assert [c.name for c in shape_cells_for(get_config("hubert-xlarge"))] \
        == ["train_4k", "prefill_32k"]
    assert "long_500k" in [c.name for c in
                           shape_cells_for(get_config("mamba2-1.3b"))]
    assert "long_500k" not in [c.name for c in
                               shape_cells_for(get_config("llama3-8b"))]
    assert LM_SHAPES["train_4k"].global_batch == 256
    assert LM_SHAPES["long_500k"].seq_len == 524_288
